"""Kernel microbenchmark: RQM / PBM quantization paths on CPU.

Times the fused-jnp production path (what the train step lowers on this
container), the Pallas interpret-mode kernel (correctness runtime), and the
(m+1)-uniforms reference — the memory-traffic argument for the in-kernel
counter-based RNG (the reference reads ~17x the bytes).

The wire-codec section times the dense b-bit pack/unpack (core/wire.py)
and the PACKED fused round sum, and records the DETERMINISTIC wire-byte
metrics next to the timings: SecAgg bytes per round and uplink bytes per
client payload, packed vs int32 lanes. Bytes are what the codec exists
to shrink — scripts/check_bench_regression.py gates on them exactly
(any increase fails; timing metrics stay threshold-warn-only because CI
containers are noisy, but bytes are arithmetic).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import rqm as rqm_lib
from repro.core import wire
from repro.core.grid import RQMParams
from repro.core.pbm import PBMParams
from repro.kernels import ops
from repro.telemetry import write_bench_json

PARAMS = RQMParams(c=1.0, delta=1.0, m=16, q=0.42)
N = 1_000_000


def _time(fn, *args, reps=5):
    fn(*args).block_until_ready()  # compile+warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps * 1e6  # us


def run(csv=print):
    x = jax.random.uniform(jax.random.key(0), (N,), jnp.float32, -1, 1)
    key = jax.random.key(1)

    us_fast = _time(lambda x: ops.rqm_fast(x, key, PARAMS), x)
    csv(f"rqm_fused_jnp_1M,{us_fast:.0f},{N/us_fast:.1f}_elts_per_us")

    us_ref = _time(jax.jit(lambda x: rqm_lib.quantize(x, key, PARAMS)), x)
    csv(f"rqm_uniforms_ref_1M,{us_ref:.0f},speedup_vs_ref={us_ref/us_fast:.2f}x")

    x_small = x[:131072]
    us_interp = _time(
        lambda x: ops.rqm(x, key, PARAMS, interpret=True), x_small, reps=2
    )
    csv(f"rqm_pallas_interpret_128k,{us_interp:.0f},interpret_mode")

    pbm_p = PBMParams(c=1.0, m=16, theta=0.25)
    us_pbm = _time(lambda x: ops.pbm_fast(x, key, pbm_p), x)
    csv(f"pbm_fused_jnp_1M,{us_pbm:.0f},{N/us_pbm:.1f}_elts_per_us")

    # batched (clients, dim) encode — the federated round engine's shape:
    # ONE fused call over the stacked batch vs a per-client vmap with
    # split keys (the pre-engine dispatch).
    clients, dim = 40, 25_000
    xb = jax.random.uniform(
        jax.random.key(3), (clients, dim), jnp.float32, -1, 1
    )

    def vmapped(xb):
        keys = jax.random.split(key, clients)
        return jax.vmap(lambda x, k: ops.rqm_fast(x, k, PARAMS))(xb, keys)

    us_batch = _time(jax.jit(lambda xb: ops.rqm_batch(xb, key, PARAMS)), xb)
    us_vmap = _time(jax.jit(vmapped), xb)
    csv(f"rqm_batched_40x25k,{us_batch:.0f},"
        f"fused_batch_vs_vmap={us_vmap/us_batch:.2f}x")

    # fused round sum — the (cohort, dim) -> (dim,) streaming reduction
    # (kernels/fused_round_kernel.py): never materializes the encoded
    # batch, so peak transient memory is O(tile) instead of O(cohort*dim).
    # XLA's temp_size_in_bytes makes the memory claim measurable here.
    rows, dim = 1024, 8192
    xr = jax.random.uniform(
        jax.random.key(4), (rows, dim), jnp.float32, -1, 1
    )

    def materialized(xb):
        z = ops.rqm_batch(xb, key, PARAMS)
        return jnp.sum(z, axis=0, dtype=jnp.int32)

    mat_jit = jax.jit(materialized)
    fus_jit = jax.jit(lambda xb: ops.rqm_round_sum(xb, key, PARAMS))
    us_mat = _time(mat_jit, xr, reps=3)
    us_fus = _time(fus_jit, xr, reps=3)
    mat_tmp = mat_jit.lower(xr).compile().memory_analysis().temp_size_in_bytes
    fus_tmp = fus_jit.lower(xr).compile().memory_analysis().temp_size_in_bytes
    csv(f"rqm_round_sum_1024x8192,{us_fus:.0f},"
        f"fused_vs_materialized={us_mat/us_fus:.2f}x;"
        f"temp_mib={fus_tmp/2**20:.2f}_vs_{mat_tmp/2**20:.2f}")

    # dense b-bit wire codec (core/wire.py): pack/unpack throughput at
    # the m=16 payload width, and the PACKED fused round sum at the
    # paper cohort (n=40 -> 10-bit sums, 3 fields/word). The byte
    # metrics alongside are deterministic — the regression checker gates
    # on them exactly.
    p_bits = wire.payload_bits(PARAMS.m)           # 4: one client's levels
    z = (jnp.arange(N, dtype=jnp.int32) * 7919) % PARAMS.m
    us_pack = _time(jax.jit(lambda z: wire.pack_bits(z, p_bits)), z)
    words = wire.pack_bits(z, p_bits)
    us_unpack = _time(
        jax.jit(lambda w: wire.unpack_bits(w, p_bits, N)), words
    )
    payload_packed = wire.packed_nbytes(N, p_bits)
    csv(f"wire_pack_1M,{us_pack:.0f},unpack={us_unpack:.0f}us;"
        f"payload_bytes={payload_packed}_vs_{N*4}_dense="
        f"{N*4/payload_packed:.1f}x")

    s_rows, s_dim = 40, 25_000
    s_bits = wire.sum_bits(s_rows * (PARAMS.m - 1))  # 10
    xs = jax.random.uniform(
        jax.random.key(5), (s_rows, s_dim), jnp.float32, -1, 1
    )
    dense_sum_jit = jax.jit(lambda xb: ops.rqm_round_sum(xb, key, PARAMS))
    packed_sum_jit = jax.jit(
        lambda xb: ops.rqm_round_sum(xb, key, PARAMS, pack_bits=s_bits)
    )
    us_sum_dense = _time(dense_sum_jit, xs, reps=3)
    us_sum_packed = _time(packed_sum_jit, xs, reps=3)
    secagg_packed = wire.packed_nbytes(s_dim, s_bits)
    csv(f"rqm_round_sum_packed_40x25k,{us_sum_packed:.0f},"
        f"dense={us_sum_dense:.0f}us;"
        f"secagg_bytes={secagg_packed}_vs_{s_dim*4}_dense="
        f"{s_dim*4/secagg_packed:.1f}x")

    return {"rqm_fast_us": us_fast, "ref_us": us_ref, "pbm_fast_us": us_pbm,
            "interpret_us": us_interp, "batch_us": us_batch,
            "vmap_us": us_vmap, "round_sum_us": us_fus,
            "round_sum_materialized_us": us_mat,
            "round_sum_temp_bytes": int(fus_tmp),
            "round_sum_materialized_temp_bytes": int(mat_tmp),
            "wire_pack_us": us_pack, "wire_unpack_us": us_unpack,
            "payload_bits": int(p_bits),
            "payload_wire_bytes": int(payload_packed),
            "payload_dense_bytes": int(N * 4),
            "round_sum_packed_us": us_sum_packed,
            "round_sum_packed_dense_us": us_sum_dense,
            "secagg_sum_bits": int(s_bits),
            "secagg_wire_bytes": int(secagg_packed),
            "secagg_dense_bytes": int(s_dim * 4)}


def bench_json(path):
    """Run the benchmark and write the machine-readable BENCH_kernels.json
    artifact in the tracker document format (docs/telemetry.md; shared by
    the CLI below and benchmarks/run.py)."""
    results = run()
    meta = {
        "benchmark": "kernel_bench",
        "backend": jax.default_backend(),
        "elements": N,
    }
    kernels = {
            "rqm_fused_jnp": {"us": results["rqm_fast_us"],
                              "elts_per_us": N / results["rqm_fast_us"]},
            "rqm_uniforms_ref": {"us": results["ref_us"]},
            "rqm_pallas_interpret_128k": {"us": results["interpret_us"]},
            "pbm_fused_jnp": {"us": results["pbm_fast_us"],
                              "elts_per_us": N / results["pbm_fast_us"]},
            "rqm_batched_40x25k": {"us": results["batch_us"],
                                   "vmap_us": results["vmap_us"]},
            "rqm_round_sum_1024x8192": {
                "us": results["round_sum_us"],
                "materialized_us": results["round_sum_materialized_us"],
                "temp_bytes": results["round_sum_temp_bytes"],
                "materialized_temp_bytes":
                    results["round_sum_materialized_temp_bytes"],
            },
            # wire_bytes keys are gated EXACTLY by the regression
            # checker: packing is arithmetic, any byte increase means
            # the codec stopped engaging (a real regression, not noise)
            "wire_pack_1M": {"us": results["wire_pack_us"],
                             "unpack_us": results["wire_unpack_us"],
                             "bits": results["payload_bits"],
                             "wire_bytes": results["payload_wire_bytes"],
                             "dense_bytes": results["payload_dense_bytes"]},
            "rqm_round_sum_packed_40x25k": {
                "us": results["round_sum_packed_us"],
                "dense_us": results["round_sum_packed_dense_us"],
                "bits": results["secagg_sum_bits"],
                "wire_bytes": results["secagg_wire_bytes"],
                "dense_bytes": results["secagg_dense_bytes"],
            },
    }
    return write_bench_json(path, meta, {"kernels": kernels})


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results (BENCH_kernels.json)")
    args = ap.parse_args()
    if args.json:
        bench_json(args.json)
    else:
        run()


if __name__ == "__main__":
    main()
