"""Kernel microbenchmark: RQM / PBM quantization paths on CPU.

Times the fused-jnp production path (what the train step lowers on this
container), the Pallas interpret-mode kernel (correctness runtime), and the
(m+1)-uniforms reference — the memory-traffic argument for the in-kernel
counter-based RNG (the reference reads ~17x the bytes).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import rqm as rqm_lib
from repro.core.grid import RQMParams
from repro.core.pbm import PBMParams
from repro.kernels import ops
from repro.telemetry import write_bench_json

PARAMS = RQMParams(c=1.0, delta=1.0, m=16, q=0.42)
N = 1_000_000


def _time(fn, *args, reps=5):
    fn(*args).block_until_ready()  # compile+warm
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps * 1e6  # us


def run(csv=print):
    x = jax.random.uniform(jax.random.key(0), (N,), jnp.float32, -1, 1)
    key = jax.random.key(1)

    us_fast = _time(lambda x: ops.rqm_fast(x, key, PARAMS), x)
    csv(f"rqm_fused_jnp_1M,{us_fast:.0f},{N/us_fast:.1f}_elts_per_us")

    us_ref = _time(jax.jit(lambda x: rqm_lib.quantize(x, key, PARAMS)), x)
    csv(f"rqm_uniforms_ref_1M,{us_ref:.0f},speedup_vs_ref={us_ref/us_fast:.2f}x")

    x_small = x[:131072]
    us_interp = _time(
        lambda x: ops.rqm(x, key, PARAMS, interpret=True), x_small, reps=2
    )
    csv(f"rqm_pallas_interpret_128k,{us_interp:.0f},interpret_mode")

    pbm_p = PBMParams(c=1.0, m=16, theta=0.25)
    us_pbm = _time(lambda x: ops.pbm_fast(x, key, pbm_p), x)
    csv(f"pbm_fused_jnp_1M,{us_pbm:.0f},{N/us_pbm:.1f}_elts_per_us")

    # batched (clients, dim) encode — the federated round engine's shape:
    # ONE fused call over the stacked batch vs a per-client vmap with
    # split keys (the pre-engine dispatch).
    clients, dim = 40, 25_000
    xb = jax.random.uniform(
        jax.random.key(3), (clients, dim), jnp.float32, -1, 1
    )

    def vmapped(xb):
        keys = jax.random.split(key, clients)
        return jax.vmap(lambda x, k: ops.rqm_fast(x, k, PARAMS))(xb, keys)

    us_batch = _time(jax.jit(lambda xb: ops.rqm_batch(xb, key, PARAMS)), xb)
    us_vmap = _time(jax.jit(vmapped), xb)
    csv(f"rqm_batched_40x25k,{us_batch:.0f},"
        f"fused_batch_vs_vmap={us_vmap/us_batch:.2f}x")

    # fused round sum — the (cohort, dim) -> (dim,) streaming reduction
    # (kernels/fused_round_kernel.py): never materializes the encoded
    # batch, so peak transient memory is O(tile) instead of O(cohort*dim).
    # XLA's temp_size_in_bytes makes the memory claim measurable here.
    rows, dim = 1024, 8192
    xr = jax.random.uniform(
        jax.random.key(4), (rows, dim), jnp.float32, -1, 1
    )

    def materialized(xb):
        z = ops.rqm_batch(xb, key, PARAMS)
        return jnp.sum(z, axis=0, dtype=jnp.int32)

    mat_jit = jax.jit(materialized)
    fus_jit = jax.jit(lambda xb: ops.rqm_round_sum(xb, key, PARAMS))
    us_mat = _time(mat_jit, xr, reps=3)
    us_fus = _time(fus_jit, xr, reps=3)
    mat_tmp = mat_jit.lower(xr).compile().memory_analysis().temp_size_in_bytes
    fus_tmp = fus_jit.lower(xr).compile().memory_analysis().temp_size_in_bytes
    csv(f"rqm_round_sum_1024x8192,{us_fus:.0f},"
        f"fused_vs_materialized={us_mat/us_fus:.2f}x;"
        f"temp_mib={fus_tmp/2**20:.2f}_vs_{mat_tmp/2**20:.2f}")
    return {"rqm_fast_us": us_fast, "ref_us": us_ref, "pbm_fast_us": us_pbm,
            "interpret_us": us_interp, "batch_us": us_batch,
            "vmap_us": us_vmap, "round_sum_us": us_fus,
            "round_sum_materialized_us": us_mat,
            "round_sum_temp_bytes": int(fus_tmp),
            "round_sum_materialized_temp_bytes": int(mat_tmp)}


def bench_json(path):
    """Run the benchmark and write the machine-readable BENCH_kernels.json
    artifact in the tracker document format (docs/telemetry.md; shared by
    the CLI below and benchmarks/run.py)."""
    results = run()
    meta = {
        "benchmark": "kernel_bench",
        "backend": jax.default_backend(),
        "elements": N,
    }
    kernels = {
            "rqm_fused_jnp": {"us": results["rqm_fast_us"],
                              "elts_per_us": N / results["rqm_fast_us"]},
            "rqm_uniforms_ref": {"us": results["ref_us"]},
            "rqm_pallas_interpret_128k": {"us": results["interpret_us"]},
            "pbm_fused_jnp": {"us": results["pbm_fast_us"],
                              "elts_per_us": N / results["pbm_fast_us"]},
            "rqm_batched_40x25k": {"us": results["batch_us"],
                                   "vmap_us": results["vmap_us"]},
            "rqm_round_sum_1024x8192": {
                "us": results["round_sum_us"],
                "materialized_us": results["round_sum_materialized_us"],
                "temp_bytes": results["round_sum_temp_bytes"],
                "materialized_temp_bytes":
                    results["round_sum_materialized_temp_bytes"],
            },
    }
    return write_bench_json(path, meta, {"kernels": kernels})


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable results (BENCH_kernels.json)")
    args = ap.parse_args()
    if args.json:
        bench_json(args.json)
    else:
        run()


if __name__ == "__main__":
    main()
