"""Quickstart: the Randomized Quantization Mechanism in five minutes.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import RQMParams, decode_sum
from repro.core.distribution import rqm_outcome_distribution
from repro.core.renyi import pbm_aggregate_epsilon, rqm_aggregate_epsilon
from repro.core.pbm import PBMParams
from repro.kernels import ops

# --- 1. quantize a "gradient" privately -----------------------------------
params = RQMParams(c=1.0, delta=1.0, m=16, q=0.42)  # paper's Sec-6 settings
print(f"RQM: m={params.m} levels on [-{params.x_max}, {params.x_max}], "
      f"{params.bits_per_coordinate:.0f} bits/coordinate, "
      f"eps_inf <= {params.epsilon_infinity():.2f} (Thm 5.2)")

grad = jax.random.uniform(jax.random.key(0), (100_000,), jnp.float32, -1, 1)
levels = ops.rqm_fast(grad, jax.random.key(1), params)  # int32 in [0, 15]
print(f"quantized {grad.size} coords -> int levels, "
      f"range [{int(levels.min())}, {int(levels.max())}]")

# --- 2. SecAgg + decode: the server only sees the SUM ----------------------
n_clients = 24
grads = jax.random.uniform(jax.random.key(2), (n_clients, 4096), jnp.float32, -1, 1)
keys = jax.random.split(jax.random.key(3), n_clients)
z = jnp.stack([ops.rqm_fast(grads[i], keys[i], params) for i in range(n_clients)])
g_hat = decode_sum(z.sum(axis=0), n_clients, params)
err = float(jnp.abs(g_hat - grads.mean(0)).mean())
print(f"decode(sum(z)) vs true mean gradient: mean |err| = {err:.4f} "
      f"(unbiased; averages out over {n_clients} clients)")

# --- 3. exact outcome distribution (Lemma 5.1) -----------------------------
pmf = rqm_outcome_distribution(0.37, params)
print(f"Lemma 5.1 pmf at x=0.37: sums to {pmf.sum():.12f}, "
      f"E[B(z)] = {(pmf * params.levels()).sum():.4f}")

# --- 4. the paper's headline: better Renyi DP than PBM ----------------------
for alpha in (2.0, 32.0):
    e_rqm = rqm_aggregate_epsilon(params, n=40, alpha=alpha)
    e_pbm = pbm_aggregate_epsilon(PBMParams(c=1.0, m=16, theta=0.25), 40, alpha)
    print(f"alpha={alpha:4.0f}, n=40: eps RQM={e_rqm:.3f} < PBM={e_pbm:.3f} "
          f"({e_pbm/e_rqm:.1f}x better)")

# --- 5. Mechanism API v2: registry-backed, self-accounting ------------------
# One spec string builds any registered mechanism; the object carries its
# params and answers its own exact Renyi accounting (no attach_params).
from repro.core.mechanisms import make_mechanism, mechanism_names

print(f"registered mechanisms: {', '.join(mechanism_names())}")
for spec in ("rqm:c=1.0,m=16,q=0.42", "pbm:c=1.0,theta=0.25",
             "qmgeo:c=1.0,m=16,r=0.6"):
    mech = make_mechanism(spec)
    z = mech.quantize(grad[:4096], jax.random.key(6))
    print(f"  {mech.describe():45s} -> per-round eps(alpha=8, n=40) = "
          f"{mech.per_round_epsilon(40, 8.0):.3f}, "
          f"{mech.bits:.0f} bits/coord")
