"""END-TO-END DRIVER — the paper's experiment (Sec 6.2 / Fig 3): federated
DP-SGD on (synthetic-)EMNIST with RQM, vs PBM and noise-free clipped SGD,
with exact Renyi accounting across rounds.

A few hundred rounds on CPU:

  PYTHONPATH=src python examples/fl_emnist.py --rounds 300
  PYTHONPATH=src python examples/fl_emnist.py --rounds 300 --mechanism rqm \\
      --delta-ratio 0.66 --q 0.33       # the paper's best (Δ,q) pair
"""
import argparse
import json

from repro.core.grid import RQMParams
from repro.core.pbm import PBMParams
from repro.core.mechanisms import make_mechanism
from repro.fed.loop import FedConfig, FedTrainer


def run_one(name, fcfg, c, m, q, delta_ratio, theta):
    """One mechanism end-to-end: train with the configured round engine,
    then report the composed Renyi accounting."""
    mech = make_mechanism(name, c=c, m=m, q=q, delta_ratio=delta_ratio,
                          theta=theta)
    tr = FedTrainer(mech, fcfg)
    if name == "rqm":
        tr.attach_params(RQMParams(c=c, delta=delta_ratio * c, m=m, q=q))
    elif name == "pbm":
        tr.attach_params(PBMParams(c=c, m=m, theta=theta))
    hist = tr.train(eval_every=25)
    out = {"mechanism": name, "history": hist}
    if name != "none":
        out["rdp_eps_alpha8"] = tr.accountant.rdp_epsilon(8.0)
        eps, alpha = tr.accountant.dp_epsilon(1e-5)
        out["dp_eps_at_1e-5"] = eps
        out["dp_alpha"] = alpha
        print(f"[{name}] total RDP eps(alpha=8) = {out['rdp_eps_alpha8']:.3f}; "
              f"(eps, delta=1e-5)-DP eps = {eps:.3f} via alpha={alpha}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--clients", type=int, default=3400)
    ap.add_argument("--per-round", type=int, default=40)
    ap.add_argument("--lr", type=float, default=1.0)
    ap.add_argument("--clip", type=float, default=0.02)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--q", type=float, default=0.42)
    ap.add_argument("--delta-ratio", type=float, default=1.0)
    ap.add_argument("--theta", type=float, default=0.25)
    ap.add_argument("--mechanism", default="all",
                    choices=["all", "rqm", "pbm", "none"])
    ap.add_argument("--engine", default="scan",
                    choices=["scan", "perround", "host"],
                    help="round engine: 'scan' = device-resident jitted "
                         "blocks (fastest), 'perround' = same step driven "
                         "per round, 'host' = legacy host loop")
    ap.add_argument("--out", default=None, help="write results JSON")
    args = ap.parse_args()

    fcfg = FedConfig(
        num_clients=args.clients, clients_per_round=args.per_round,
        rounds=args.rounds, lr=args.lr, eval_size=1000,
        data_noise=1.5, data_deform=1.2,  # see benchmarks/fig3_fl_emnist.py
        engine=args.engine,
    )
    names = ["none", "rqm", "pbm"] if args.mechanism == "all" else [args.mechanism]
    results = [
        run_one(n, fcfg, args.clip, args.m, args.q, args.delta_ratio,
                args.theta)
        for n in names
    ]
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print("wrote", args.out)


if __name__ == "__main__":
    main()
