"""END-TO-END DRIVER — the paper's experiment (Sec 6.2 / Fig 3): federated
DP-SGD on (synthetic-)EMNIST with RQM, vs PBM, the QMGeo-style
truncated-geometric quantizer, and noise-free clipped SGD, with exact
Renyi accounting across rounds.

A few hundred rounds on CPU:

  PYTHONPATH=src python examples/fl_emnist.py --rounds 300
  PYTHONPATH=src python examples/fl_emnist.py --rounds 300 --mechanism rqm \\
      --delta-ratio 0.66 --q 0.33       # the paper's best (Δ,q) pair
  PYTHONPATH=src python examples/fl_emnist.py --rounds 300 \\
      --mechanism "qmgeo:c=0.02,m=16,r=0.6"   # any registered spec string

Privacy is SELF-ACCOUNTED: the mechanism object that encodes also answers
``per_round_epsilon(n, alpha)``, so the reported accuracy-vs-epsilon
tradeoff is computed from the exact parameters that produced the updates.

Backwards mode (--target-eps): instead of specifying privacy knobs, give a
budget and let repro.privacy.calibrate solve each family's knob so the
whole run composes to the target (eps, --target-delta)-DP; the trainer
then logs the remaining budget and halts at exhaustion. Realistic cohorts:
--subsampling poisson samples each client i.i.d. per round, --dropout
drops selected clients i.i.d. — accounting composes at the realized size.

  PYTHONPATH=src python examples/fl_emnist.py --rounds 300 \\
      --target-eps 30 --subsampling poisson --dropout 0.1
"""
import argparse
import dataclasses
import hashlib
import json
import os

from repro.core.mechanisms import (
    accepted_options,
    make_mechanism,
    mechanism_names,
    parse_mechanism_spec,
)
from repro.fed import FedConfig, FedTrainer
from repro.fed.engine import engine_names
from repro.privacy.calibrate import DEFAULT_ALPHAS, calibrate, calibration_knobs
from repro.telemetry import parse_tracker_spec


def _suffix_track_spec(spec: str, tag: str) -> str:
    """Per-mechanism tracker paths in a multi-mechanism sweep: insert the
    mechanism tag before the extension of every path in the spec — the
    same no-interleaving rule the per-mechanism checkpoint subdirs follow."""
    parts = []
    for sub in spec.split("+"):
        name, opts = parse_tracker_spec(sub)
        if opts.get("path"):
            root, ext = os.path.splitext(str(opts["path"]))
            opts["path"] = f"{root}-{tag}{ext}"
        body = ",".join(f"{k}={v}" for k, v in opts.items())
        parts.append(f"{name}:{body}" if body else name)
    return "+".join(parts)


def run_one(spec, fcfg, target_eps=None, resume=False, track=None,
            multi=False, **defaults):
    """One mechanism end-to-end: build from the spec (or calibrate the
    family to --target-eps), train with the configured round engine
    (resuming from the mechanism's checkpoint directory when asked),
    report the mechanism's own accounting."""
    calibrated = None
    name, explicit = parse_mechanism_spec(spec)
    if target_eps is not None and name in calibration_knobs():
        # spec strings participate too: inline options become fixed
        # calibration options — fixing the knob itself inside the spec
        # conflicts with solving for it, and calibrate() raises on it
        knob = calibration_knobs()[name]
        opts = {k: v for k, v in defaults.items()
                if k in accepted_options(name) and k != knob.option}
        opts.update(explicit)
        calibrated = calibrate(
            name, target_eps=target_eps, target_delta=fcfg.budget_delta,
            rounds=fcfg.rounds, cohort=fcfg.clients_per_round, **opts,
        )
        mech = calibrated.mechanism
        print(f"[{name}] calibrated: {calibrated.describe()}")
    else:
        mech = make_mechanism(spec, **defaults)
    # one artifact per FULL mechanism spec (family name + an 8-hex digest
    # of the exact parameters): a multi-mechanism sweep must not
    # interleave checkpoints or tracker series, and two runs of the same
    # family with different knobs (or different calibrations) must not
    # clobber each other's files
    tag = f"{name}-{hashlib.sha256(mech.describe().encode()).hexdigest()[:8]}"
    if fcfg.ckpt_dir:
        fcfg = dataclasses.replace(
            fcfg, ckpt_dir=os.path.join(fcfg.ckpt_dir, tag)
        )
    if track and multi:
        track = _suffix_track_spec(track, tag)
    tr = FedTrainer(mech, fcfg, tracker=track)
    remaining = fcfg.rounds
    if resume:
        try:
            restored = tr.restore_checkpoint()
        except FileNotFoundError:
            print(f"[{name}] no checkpoints in {fcfg.ckpt_dir}; "
                  f"starting fresh")
        else:
            remaining = max(fcfg.rounds - restored, 0)
            if remaining == 0:
                print(f"[{name}] checkpoint at round {restored} already "
                      f"covers --rounds {fcfg.rounds}; nothing to train "
                      f"(reporting the restored state)")
            else:
                print(f"[{name}] resumed from round {restored} "
                      f"({fcfg.ckpt_dir}); {remaining} rounds to go")
    hist = tr.train(rounds=remaining, eval_every=25)
    if not hist:
        # nothing left to train (resume at/beyond --rounds): still report
        # the restored model instead of an empty history
        m = tr.evaluate()
        m["round"] = tr.accountant.rounds
        if fcfg.budget_eps is not None:
            m["eps_spent"], m["eps_remaining"] = tr.budget_spent()
        hist = [m]
    out = {"mechanism": mech.name, "spec": mech.describe(), "history": hist}
    if calibrated is not None:
        out["calibration"] = {
            "target_eps": calibrated.target_eps, "knob": calibrated.knob,
            "value": calibrated.value, "epsilon": calibrated.epsilon,
        }
    if tr.realized_n and min(tr.realized_n) != max(tr.realized_n):
        out["realized_cohorts"] = {
            "min": min(tr.realized_n), "max": max(tr.realized_n),
            "mean": sum(tr.realized_n) / len(tr.realized_n),
        }
    tr.tracker.close()
    per_round = mech.per_round_epsilon(fcfg.clients_per_round, 8.0)
    if per_round > 0:
        out["per_round_eps_alpha8"] = per_round
        out["rdp_eps_alpha8"] = tr.accountant.rdp_epsilon(8.0)
        eps, alpha = tr.accountant.dp_epsilon(1e-5)
        out["dp_eps_at_1e-5"] = eps
        out["dp_alpha"] = alpha
        print(f"[{mech.name}] total RDP eps(alpha=8) = {out['rdp_eps_alpha8']:.3f}; "
              f"(eps, delta=1e-5)-DP eps = {eps:.3f} via alpha={alpha}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--clients", type=int, default=3400)
    ap.add_argument("--per-round", type=int, default=40)
    ap.add_argument("--lr", type=float, default=1.0)
    ap.add_argument("--clip", type=float, default=0.02)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--q", type=float, default=0.42)
    ap.add_argument("--delta-ratio", type=float, default=1.0)
    ap.add_argument("--theta", type=float, default=0.25)
    ap.add_argument("--r", type=float, default=0.6)
    ap.add_argument("--mechanism", default="all",
                    help="'all', a registered name "
                         f"({', '.join(mechanism_names())}), or a "
                         "'name:k=v,...' spec string; the flags above act "
                         "as defaults for whatever the spec leaves unset")
    ap.add_argument("--engine", default="scan",
                    help="round engine: a registered name "
                         f"({', '.join(engine_names())}) or a "
                         "'name:k=v,...' spec string, e.g. "
                         "'async:cadence=16,max_staleness=4' "
                         "(docs/engines.md, docs/async.md): 'scan' = "
                         "device-resident jitted blocks (fastest on one "
                         "device), 'shard' = scan blocks sharded over all "
                         "visible devices with encoded-domain cross-shard "
                         "aggregation (docs/scaling.md), 'perround' = "
                         "same step driven per round, 'host' = legacy "
                         "host loop, 'async' = traffic-shaped buffered "
                         "aggregation")
    ap.add_argument("--server-opt", default="sgd",
                    help="server optimizer at the decode-then-apply "
                         "boundary: 'sgd' (the paper's w - lr*g_hat), "
                         "'momentum', or 'adam'; state rides the jitted "
                         "carry and checkpoints with the params")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (per-mechanism subdirs); "
                         "enables --ckpt-every and --resume")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint every N rounds (requires --ckpt-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint from --ckpt-dir "
                         "and continue BIT-IDENTICALLY to the "
                         "uninterrupted run (params + epsilon sequence)")
    ap.add_argument("--shards", type=int, default=None,
                    help="engine=shard: cohort shards (default: all devices)")
    ap.add_argument("--staging", default="full", choices=["full", "stream"],
                    help="engine=shard: 'stream' stages only each block's "
                         "active cohort (bounded memory for huge "
                         "populations)")
    ap.add_argument("--fused-rounds", action="store_true",
                    help="stream the round's clip->encode->sum through the "
                         "fused kernel (docs/kernels.md): never materializes "
                         "the (cohort, dim) encoded batch, bit-identical "
                         "results (scan/perround/shard engines)")
    ap.add_argument("--subsampling", default="fixed",
                    choices=["fixed", "poisson"],
                    help="cohort realization: 'poisson' includes each "
                         "client i.i.d. at rate per_round/clients; the "
                         "accountant composes each round at its REALIZED "
                         "cohort size (docs/privacy.md)")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="i.i.d. per-selected-client dropout probability; "
                         "survivors are what the round is accounted at")
    ap.add_argument("--target-eps", type=float, default=None,
                    help="calibrate each private mechanism family to this "
                         "total (eps, --target-delta)-DP budget over "
                         "--rounds rounds (privacy knobs --q/--theta/--r "
                         "are then solved for, and the trainer halts at "
                         "budget exhaustion)")
    ap.add_argument("--target-delta", type=float, default=1e-5)
    ap.add_argument("--track", default=None,
                    help="tracker spec (make_mechanism-style, "
                         "docs/telemetry.md): 'json:runs/fl.json', "
                         "'csv:runs/fl.csv', or a '+'-joined composite; "
                         "per-round eps/accuracy series land there. With "
                         "--mechanism all, each mechanism writes its own "
                         "suffixed file (like the checkpoint subdirs)")
    ap.add_argument("--out", default=None, help="write results JSON")
    args = ap.parse_args()
    if args.resume and not args.ckpt_dir:
        ap.error("--resume requires --ckpt-dir")

    fcfg = FedConfig(
        num_clients=args.clients, clients_per_round=args.per_round,
        rounds=args.rounds, lr=args.lr, eval_size=1000,
        data_noise=1.5, data_deform=1.2,  # see benchmarks/fig3_fl_emnist.py
        engine=args.engine, shards=args.shards, staging=args.staging,
        fused_rounds=args.fused_rounds,
        server_opt=args.server_opt,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        subsampling=args.subsampling, dropout=args.dropout,
        budget_eps=args.target_eps, budget_delta=args.target_delta,
        # budget mode: account on the same alpha grid calibration optimizes
        # over, so the run can afford exactly the calibrated round count
        accountant_alphas=(tuple(DEFAULT_ALPHAS) if args.target_eps is not None
                           else FedConfig.accountant_alphas),
    )
    specs = (["none", "rqm", "pbm", "qmgeo"] if args.mechanism == "all"
             else [args.mechanism])
    defaults = dict(c=args.clip, m=args.m, q=args.q,
                    delta_ratio=args.delta_ratio, theta=args.theta, r=args.r)
    results = [run_one(s, fcfg, target_eps=args.target_eps,
                       resume=args.resume, track=args.track,
                       multi=len(specs) > 1, **defaults)
               for s in specs]
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print("wrote", args.out)


if __name__ == "__main__":
    main()
