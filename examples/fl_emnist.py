"""END-TO-END DRIVER — the paper's experiment (Sec 6.2 / Fig 3): federated
DP-SGD on (synthetic-)EMNIST with RQM, vs PBM, the QMGeo-style
truncated-geometric quantizer, and noise-free clipped SGD, with exact
Renyi accounting across rounds.

A few hundred rounds on CPU:

  PYTHONPATH=src python examples/fl_emnist.py --rounds 300
  PYTHONPATH=src python examples/fl_emnist.py --rounds 300 --mechanism rqm \\
      --delta-ratio 0.66 --q 0.33       # the paper's best (Δ,q) pair
  PYTHONPATH=src python examples/fl_emnist.py --rounds 300 \\
      --mechanism "qmgeo:c=0.02,m=16,r=0.6"   # any registered spec string

Privacy is SELF-ACCOUNTED: the mechanism object that encodes also answers
``per_round_epsilon(n, alpha)``, so the reported accuracy-vs-epsilon
tradeoff is computed from the exact parameters that produced the updates.
"""
import argparse
import json

from repro.core.mechanisms import make_mechanism, mechanism_names
from repro.fed.loop import FedConfig, FedTrainer


def run_one(spec, fcfg, **defaults):
    """One mechanism end-to-end: build from the spec, train with the
    configured round engine, report the mechanism's own accounting."""
    mech = make_mechanism(spec, **defaults)
    tr = FedTrainer(mech, fcfg)
    hist = tr.train(eval_every=25)
    out = {"mechanism": mech.name, "spec": mech.describe(), "history": hist}
    per_round = mech.per_round_epsilon(fcfg.clients_per_round, 8.0)
    if per_round > 0:
        out["per_round_eps_alpha8"] = per_round
        out["rdp_eps_alpha8"] = tr.accountant.rdp_epsilon(8.0)
        eps, alpha = tr.accountant.dp_epsilon(1e-5)
        out["dp_eps_at_1e-5"] = eps
        out["dp_alpha"] = alpha
        print(f"[{mech.name}] total RDP eps(alpha=8) = {out['rdp_eps_alpha8']:.3f}; "
              f"(eps, delta=1e-5)-DP eps = {eps:.3f} via alpha={alpha}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--clients", type=int, default=3400)
    ap.add_argument("--per-round", type=int, default=40)
    ap.add_argument("--lr", type=float, default=1.0)
    ap.add_argument("--clip", type=float, default=0.02)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--q", type=float, default=0.42)
    ap.add_argument("--delta-ratio", type=float, default=1.0)
    ap.add_argument("--theta", type=float, default=0.25)
    ap.add_argument("--r", type=float, default=0.6)
    ap.add_argument("--mechanism", default="all",
                    help="'all', a registered name "
                         f"({', '.join(mechanism_names())}), or a "
                         "'name:k=v,...' spec string; the flags above act "
                         "as defaults for whatever the spec leaves unset")
    ap.add_argument("--engine", default="scan",
                    choices=["scan", "perround", "host", "shard"],
                    help="round engine: 'scan' = device-resident jitted "
                         "blocks (fastest on one device), 'shard' = scan "
                         "blocks sharded over all visible devices with "
                         "encoded-domain cross-shard aggregation (see "
                         "docs/scaling.md), 'perround' = same step driven "
                         "per round, 'host' = legacy host loop")
    ap.add_argument("--shards", type=int, default=None,
                    help="engine=shard: cohort shards (default: all devices)")
    ap.add_argument("--staging", default="full", choices=["full", "stream"],
                    help="engine=shard: 'stream' stages only each block's "
                         "active cohort (bounded memory for huge "
                         "populations)")
    ap.add_argument("--out", default=None, help="write results JSON")
    args = ap.parse_args()

    fcfg = FedConfig(
        num_clients=args.clients, clients_per_round=args.per_round,
        rounds=args.rounds, lr=args.lr, eval_size=1000,
        data_noise=1.5, data_deform=1.2,  # see benchmarks/fig3_fl_emnist.py
        engine=args.engine, shards=args.shards, staging=args.staging,
    )
    specs = (["none", "rqm", "pbm", "qmgeo"] if args.mechanism == "all"
             else [args.mechanism])
    defaults = dict(c=args.clip, m=args.m, q=args.q,
                    delta_ratio=args.delta_ratio, theta=args.theta, r=args.r)
    results = [run_one(s, fcfg, **defaults) for s in specs]
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print("wrote", args.out)


if __name__ == "__main__":
    main()
