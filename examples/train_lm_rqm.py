"""Train a language model with RQM in the loop — the framework's distributed
train step (grad -> clip -> RQM -> SecAgg-psum -> decode -> SGD), runnable
on CPU with a reduced architecture, on a mesh with --mesh-shape.

  PYTHONPATH=src python examples/train_lm_rqm.py --arch qwen3-moe-30b-a3b \\
      --steps 150 --compare
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core.mechanisms import make_mechanism
from repro.data.lm import TokenPipeline
from repro.distributed.step import build_train_step_fn
from repro.models import model as model_lib
from repro.models.common import ParallelCtx
from repro.optim import make_optimizer
from repro.optim.schedules import warmup_cosine


def run(arch, mechanism, steps, batch, seq, clip, lr, seed=0, log=True):
    cfg = get_config(arch, reduced=True)
    mech = make_mechanism(mechanism, c=clip)
    opt = make_optimizer("sgd")
    ctx = ParallelCtx()
    step_fn = jax.jit(build_train_step_fn(
        cfg, mech, opt, warmup_cosine(lr, steps // 10 + 1, steps), ctx,
        remat=False, compute_dtype=jnp.float32,
    ), donate_argnums=(0, 1))
    params = model_lib.init_params(jax.random.key(seed), cfg, tp=1)
    opt_state = opt.init(params)
    pipe = TokenPipeline(cfg, seq, batch, seed=seed)
    key = jax.random.key(seed + 1)
    losses = []
    for step in range(steps):
        b = {k: jnp.asarray(v) for k, v in pipe.batch(step).items()}
        key, sub = jax.random.split(key)
        params, opt_state, m = step_fn(params, opt_state, jnp.int32(step), b, sub)
        losses.append(float(m["ce_loss"]))
        if log and ((step + 1) % 25 == 0 or step == 0):
            print(f"  [{mechanism:5s}] step {step+1:4d} ce={losses[-1]:.4f}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--clip", type=float, default=0.02)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--mechanism", default="rqm")
    ap.add_argument("--compare", action="store_true",
                    help="run rqm vs pbm vs noise-free")
    args = ap.parse_args()

    names = ["none", "rqm", "pbm"] if args.compare else [args.mechanism]
    final = {}
    for n in names:
        print(f"training {args.arch} with mechanism={n}")
        losses = run(args.arch, n, args.steps, args.batch, args.seq,
                     args.clip, args.lr)
        final[n] = losses[-1]
    print("final ce:", {k: round(v, 4) for k, v in final.items()})


if __name__ == "__main__":
    main()
