"""Serve a small model with batched requests: continuous-batching-style demo
on the framework's prefill/decode runtime (reduced configs, CPU).

  PYTHONPATH=src python examples/serve_demo.py --arch gemma3-4b --requests 6
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape
from repro.configs.registry import get_config
from repro.models import model as model_lib
from repro.models.common import ParallelCtx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    ctx = ParallelCtx()
    params = model_lib.init_params(jax.random.key(0), cfg, tp=1)
    cap = args.prompt_len + args.gen
    shape = InputShape("serve", cap, args.batch, "decode")
    Pfx = cfg.frontend.prefix_len if cfg.frontend else 0

    prefill = jax.jit(lambda p, t, e: model_lib.prefill(
        p, cfg, ctx, t, shape, prefix_embeds=e, compute_dtype=jnp.float32))
    decode = jax.jit(lambda p, c, t, pos: model_lib.decode_step(
        p, c, cfg, ctx, t, pos, compute_dtype=jnp.float32))

    # request queue -> fixed-size batches (simple static batching)
    rng = np.random.default_rng(0)
    queue = [rng.integers(0, cfg.vocab_size,
                          size=args.prompt_len - Pfx).astype(np.int32)
             for _ in range(args.requests)]
    served, t0 = 0, time.time()
    while queue:
        batch_reqs = [queue.pop(0) for _ in range(min(args.batch, len(queue)))]
        while len(batch_reqs) < args.batch:  # pad the last batch
            batch_reqs.append(batch_reqs[-1])
        toks = jnp.asarray(np.stack(batch_reqs))
        pe = (jnp.zeros((args.batch, Pfx, cfg.d_model), jnp.float32)
              if Pfx else None)
        nxt, caches = prefill(params, toks, pe)
        outs = [np.asarray(nxt)]
        for i in range(args.gen - 1):
            nxt, caches = decode(params, caches, nxt[:, None],
                                 jnp.int32(args.prompt_len + i))
            outs.append(np.asarray(nxt))
        gen = np.stack(outs, axis=1)
        served += len(batch_reqs)
        print(f"batch done: generated {gen.shape[1]} tokens x "
              f"{gen.shape[0]} requests; sample: {gen[0][:10].tolist()}")
    dt = time.time() - t0
    print(f"served {served} requests ({served*args.gen} tokens) "
          f"in {dt:.1f}s = {served*args.gen/dt:,.0f} tok/s")


if __name__ == "__main__":
    main()
